"""Multi-tenant key management + tenant-isolated serving.

Covers the tenancy subsystem's guarantees:
  * key hierarchy — deterministic, purpose/tenant/epoch-separated
    derivation; rotation bumps epochs and destroys dropped material;
  * registry — session validation/revocation, retained-epoch windows,
    key-bank row management;
  * isolation — a page written under tenant A's keys fails
    verification when read under tenant B's (pool-level and
    engine-level), and a stale-epoch replay after rotation is
    rejected;
  * rotation — post-rotation decode is token-identical to an
    unrotated run (lazy re-encryption is transparent);
  * scheduling — quota-exceeded admission queues instead of evicting
    other tenants; memory pressure evicts tenant-scoped; weighted-fair
    admission favors heavier tenants;
  * parity — >=3 tenants interleaved on one engine produce
    token-identical output to the single-tenant baseline for every
    scheme in SCHEMES.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve.engine import IntegrityError, SecureServingEngine
from repro.tenancy import KeyHierarchy, TenantRegistry
from repro.tenancy.keys import prf


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (5, 7, 9)]


def _registry(n=3, seed=3):
    reg = TenantRegistry(KeyHierarchy(seed), max_tenants=max(n, 2))
    sessions = []
    for i in range(n):
        reg.register(f"t{i}")
        sessions.append(reg.open_session(f"t{i}"))
    return reg, sessions


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 3)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("scheme", "seda")
    return SecureServingEngine(arch, cfg, params, **kw)


class TestKeyHierarchy:
    def test_derivation_deterministic_and_separated(self):
        h1, h2 = KeyHierarchy(11), KeyHierarchy(11)
        a1, a2 = h1.derive_tenant("alice"), h2.derive_tenant("alice")
        b = h1.derive_tenant("bob")
        np.testing.assert_array_equal(a1.master, a2.master)
        assert not np.array_equal(a1.master, b.master)
        # Purpose split: enc/mac/vn keys all distinct.
        trio = [a1.enc_key, a1.mac_key, a1.vn_key]
        for i in range(3):
            for j in range(i + 1, 3):
                assert not np.array_equal(trio[i], trio[j])

    def test_epoch_keys_distinct_and_rotation_drops(self):
        ks = KeyHierarchy(5).derive_tenant("t")
        k0, k1 = ks.epoch_keys(0), None
        assert ks.rotate() == 1
        k1 = ks.epoch_keys(1)
        assert not np.array_equal(np.asarray(k0.key), np.asarray(k1.key))
        assert not np.array_equal(np.asarray(k0.hash_key),
                                  np.asarray(k1.hash_key))
        ks.drop_before(1)
        with pytest.raises(KeyError):
            ks.epoch_keys(0)

    def test_prf_is_a_function_of_key_and_message(self):
        k1 = np.arange(16, dtype=np.uint8)
        k2 = k1 ^ 1
        assert not np.array_equal(prf(k1, b"x"), prf(k2, b"x"))
        assert not np.array_equal(prf(k1, b"x"), prf(k1, b"y"))
        np.testing.assert_array_equal(prf(k1, b"x"), prf(k1, b"x"))


class TestRegistry:
    def test_sessions_validate_and_revoke(self):
        reg, (s0, *_) = _registry(2)
        assert reg.validate(s0).tenant_id == "t0"
        reg.revoke(s0)
        with pytest.raises(PermissionError):
            reg.validate(s0)
        forged = s0._replace(token=999)
        with pytest.raises(PermissionError):
            reg.validate(forged)

    def test_key_row_window_and_rotation(self):
        reg, _ = _registry(1)
        row0 = reg.key_row(0, 0)
        reg.rotate("t0")
        assert reg.key_row(0, 1) != row0       # new epoch, sibling row
        assert reg.key_row(0, 0) == row0       # previous epoch retained
        reg.rotate("t0")
        with pytest.raises(KeyError):
            reg.key_row(0, 0)                  # fell out of the window
        # The bank row that held epoch 0 now carries epoch 2's keys.
        k2 = reg.keys_for(0, 2)
        np.testing.assert_array_equal(
            np.asarray(reg.bank.key[reg.key_row(0, 2)]), np.asarray(k2.key))

    def test_registration_limits(self):
        reg, _ = _registry(2)
        with pytest.raises(ValueError):
            reg.register("t0")                 # duplicate
        with pytest.raises(ValueError):
            reg.register("t2")                 # registry full (max 2)
        with pytest.raises(ValueError):
            TenantRegistry(KeyHierarchy(0), retain=1)  # would drop prev key


class TestPoolIsolation:
    """kv_pages-level: wrong tenant / wrong epoch fails verification."""

    def _spec(self, scheme):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        return kvp.build_page_spec(tree, scheme=scheme, page_tokens=4,
                                   n_pages=6, max_slots=2, max_len=16)

    def _ctx(self, reg, index, epoch, n):
        row = reg.key_row(index, epoch)
        return kvp.PageKeyCtx.make(reg.bank, [row] * n, [index] * n,
                                   [epoch] * n)

    @pytest.mark.parametrize("scheme", ["seda", "sgx64", "mgx512"])
    def test_cross_tenant_and_stale_epoch_fail(self, rng, scheme):
        reg, _ = _registry(2)
        spec = self._spec(scheme)
        pool = kvp.init_pool(spec)
        data = [jnp.asarray(rng.standard_normal((2, 1, 16, 2, 8)),
                            jnp.float32) for _ in spec.leaves]
        ids = jnp.asarray([0, 1, 2, 3], jnp.int32)
        pool = kvp.write_prefill(pool, spec, None, ids, data, 4,
                                 jnp.uint32(1), self._ctx(reg, 0, 0, 4))
        table = jnp.asarray([[0, 1, 2, 3], [-1] * 4], jnp.int32)
        lens = jnp.asarray([16, 0], jnp.int32)
        # Right tenant, right epoch: verifies and roundtrips.
        dense, ok = kvp.read_pages(pool, spec, None, table, lens,
                                   self._ctx(reg, 0, 0, 8))
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(dense[0][:, 0]),
                                      np.asarray(data[0][:, 0]))
        # Wrong tenant: MAC gate fails.
        _, ok_b = kvp.read_pages(pool, spec, None, table, lens,
                                 self._ctx(reg, 1, 0, 8))
        assert not bool(ok_b)
        # Wrong epoch (same tenant): rotate, then read the old pages
        # claiming they were written at the NEW epoch.
        reg.rotate("t0")
        _, ok_e = kvp.read_pages(pool, spec, None, table, lens,
                                 self._ctx(reg, 0, 1, 8))
        assert not bool(ok_e)
        # Old epoch still retained: the honest read still verifies.
        _, ok_r = kvp.read_pages(pool, spec, None, table, lens,
                                 self._ctx(reg, 0, 0, 8))
        assert bool(ok_r)


class TestEngineIsolation:
    def test_cross_tenant_page_read_raises(self, smoke, prompts):
        reg, sess = _registry(2, seed=9)
        eng = _engine(smoke, max_slots=2, registry=reg)
        r0 = eng.submit(prompts[0], max_new_tokens=6, session=sess[0])
        r1 = eng.submit(prompts[1], max_new_tokens=6, session=sess[1])
        eng.step()
        s0 = next(s for s in eng.slots if s and s.req.rid == r0)
        s1 = next(s for s in eng.slots if s and s.req.rid == r1)
        s1.pages, s1.page_epochs = list(s0.pages), list(s0.page_epochs)
        with pytest.raises(IntegrityError):
            eng.step()

    def test_stale_epoch_replay_after_rotation_rejected(self, smoke,
                                                        prompts):
        reg, sess = _registry(1, seed=9)
        eng = _engine(smoke, max_slots=1, registry=reg)
        eng.submit([3, 1, 4, 1, 5], max_new_tokens=8, session=sess[0])
        eng.step()
        slot = eng.slots[0]
        dirty_pid = slot.pages[slot.length // eng.page_tokens]
        old_row = np.asarray(eng.pool.cts[0][dirty_pid]).copy()
        eng.rotate("t0")
        eng.step()            # dirty write re-encrypts under epoch 1
        # Replay the pre-rotation ciphertext: the host mirror says the
        # page is at the new epoch, the bytes are from the old one.
        eng.pool = eng.pool._replace(
            cts=(eng.pool.cts[0].at[dirty_pid].set(jnp.asarray(old_row)),)
            + eng.pool.cts[1:])
        with pytest.raises(IntegrityError):
            eng.step()

    def test_forged_out_of_window_epoch_rejected(self, smoke, prompts):
        reg, sess = _registry(1, seed=9)
        eng = _engine(smoke, max_slots=1, registry=reg)
        eng.submit(prompts[0], max_new_tokens=6, session=sess[0])
        eng.step()
        eng.slots[0].page_epochs[0] = 7        # epoch that never existed
        with pytest.raises(IntegrityError):
            eng.step()

    def test_submit_requires_valid_session(self, smoke, prompts):
        reg, sess = _registry(1)
        eng = _engine(smoke, registry=reg)
        with pytest.raises(PermissionError):
            eng.submit(prompts[0], max_new_tokens=4)
        reg.revoke(sess[0])
        with pytest.raises(PermissionError):
            eng.submit(prompts[0], max_new_tokens=4, session=sess[0])
        # And a single-tenant engine refuses stray sessions.
        solo = _engine(smoke)
        with pytest.raises(ValueError):
            solo.submit(prompts[0], max_new_tokens=4, session=sess[0])


class TestParityAndRotation:
    def _baseline(self, smoke, prompts, scheme, gen=4):
        eng = _engine(smoke, scheme=scheme)
        rids = [eng.submit(p, max_new_tokens=gen) for p in prompts]
        return [eng.run()[r].generated for r in rids]

    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_three_tenants_token_identical(self, smoke, prompts, scheme):
        want = self._baseline(smoke, prompts, scheme)
        reg, sess = _registry(3)
        eng = _engine(smoke, scheme=scheme, registry=reg)
        rids = [eng.submit(p, max_new_tokens=4, session=s)
                for p, s in zip(prompts, sess)]
        done = eng.run()
        assert [done[r].generated for r in rids] == want

    def test_rotation_repairs_all_engines_sharing_registry(self, smoke,
                                                           prompts):
        # Rotation hooks run on EVERY engine attached to the registry:
        # dropping an epoch can never strand another engine's resident
        # pages on a key that no longer exists.
        reg, (s0,) = _registry(1, seed=8)
        ea = _engine(smoke, max_slots=1, registry=reg)
        eb = _engine(smoke, max_slots=1, registry=reg)
        ra = ea.submit(prompts[0], max_new_tokens=8, session=s0)
        rb = eb.submit(prompts[0], max_new_tokens=8, session=s0)
        ea.step()
        eb.step()
        ea.rotate("t0")
        ea.rotate("t0")                # epoch-0 keys are dropped now
        assert eb.stats["rotations"] == 2
        assert len(eb.run()[rb].generated) == 8   # repaired, not stranded
        assert len(ea.run()[ra].generated) == 8

    def test_post_rotation_decode_token_identical(self, smoke, prompts):
        want = self._baseline(smoke, prompts, "seda", gen=6)
        reg, sess = _registry(3)
        eng = _engine(smoke, scheme="seda", registry=reg, rotate_every=2)
        rids = [eng.submit(p, max_new_tokens=6, session=s)
                for p, s in zip(prompts, sess)]
        done = eng.run()
        assert eng.stats["rotations"] > 0
        assert [done[r].generated for r in rids] == want
        assert eng.deferred_check()


class TestMixedKeyFusedPath:
    """MIXED-row ticks keep the fused Pallas kernel (per-page round-key
    gather from the bank) instead of falling back to the vmapped
    reference — and stay bit-identical to it."""

    def _run(self, smoke, prompts, use_kernel):
        reg, sess = _registry(3, seed=11)
        eng = _engine(smoke, scheme="seda", registry=reg,
                      use_kernel=use_kernel)
        rids = [eng.submit(p, max_new_tokens=6, session=s)
                for p, s in zip(prompts, sess)]
        done = eng.run()
        return [done[r].generated for r in rids], eng

    def test_mixed_tenant_tick_fused_vs_ref_bit_identical(self, smoke,
                                                          prompts):
        want, ref_eng = self._run(smoke, prompts, use_kernel=False)
        got, fused_eng = self._run(smoke, prompts, use_kernel=True)
        assert got == want
        # Three tenants share every tick: no uniform ticks, and the
        # kernel engine must have routed them through the mixed fused
        # path (the reference engine must not report any).
        assert fused_eng.stats["uniform_fast_ticks"] == 0
        assert fused_eng.stats["fused_mixed_ticks"] > 0
        assert fused_eng.stats["fused_mixed_ticks"] == \
            fused_eng.stats["decode_steps"]
        assert ref_eng.stats["fused_mixed_ticks"] == 0
        # ... and the WRITE half too: every mixed tick's dirty-page
        # reseal ran the one-pass fused write kernel.
        assert fused_eng.stats["fused_write_ticks"] == \
            fused_eng.stats["decode_steps"]
        assert ref_eng.stats["fused_write_ticks"] == 0

    def test_mixed_fused_write_pool_bit_identical_to_ref(self, smoke,
                                                         prompts):
        """The mixed fused write's pool state (ciphertext under each
        page's own tenant-epoch keys, page/pool MACs, VNs) is
        byte-for-byte the vmapped per-page reference's."""
        want, ref_eng = self._run(smoke, prompts, use_kernel=False)
        got, fused_eng = self._run(smoke, prompts, use_kernel=True)
        assert got == want
        for a, b in zip(ref_eng.pool.cts, fused_eng.pool.cts):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(ref_eng.pool.page_macs),
                                      np.asarray(fused_eng.pool.page_macs))
        np.testing.assert_array_equal(np.asarray(ref_eng.pool.page_vns),
                                      np.asarray(fused_eng.pool.page_vns))
        np.testing.assert_array_equal(np.asarray(ref_eng.pool.pool_mac),
                                      np.asarray(fused_eng.pool.pool_mac))
        assert fused_eng.deferred_check()

    def test_mixed_fused_post_rotation_parity(self, smoke, prompts):
        """Live rotation (lazy re-encryption + eager reseal) keeps the
        kernel engine token-identical to the reference engine."""
        outs = []
        for use_kernel in (False, True):
            reg, sess = _registry(3, seed=13)     # same seed: same keys
            eng = _engine(smoke, scheme="seda", registry=reg,
                          use_kernel=use_kernel, rotate_every=2)
            rids = [eng.submit(p, max_new_tokens=6, session=s)
                    for p, s in zip(prompts, sess)]
            done = eng.run()
            assert eng.stats["rotations"] > 0
            outs.append([done[r].generated for r in rids])
        assert outs[0] == outs[1]

    def test_mixed_fused_rejects_cross_tenant_read(self, smoke, prompts):
        """The fused mixed path keeps the isolation gate: remapping a
        resident page to another tenant's slot fails verification."""
        reg, sess = _registry(2, seed=12)
        eng = _engine(smoke, scheme="seda", registry=reg, use_kernel=True,
                      max_slots=2)
        eng.submit(prompts[0], max_new_tokens=8, session=sess[0])
        eng.submit(prompts[1], max_new_tokens=8, session=sess[1])
        eng.step()
        s0, s1 = eng.slots[0], eng.slots[1]
        s1.pages[0] = s0.pages[0]       # tenant B's table points at A's page
        with pytest.raises(IntegrityError):
            eng.run()

    def test_fused_write_rejects_cross_tenant_read(self, smoke, prompts):
        """A page RESEALED by the fused mixed write (not just the
        prefill write) keeps tenant isolation: steal the dirty page
        after a fused-write tick and the victim's binding still wins."""
        reg, sess = _registry(2, seed=14)
        eng = _engine(smoke, scheme="seda", registry=reg, use_kernel=True,
                      max_slots=2)
        eng.submit(prompts[0], max_new_tokens=8, session=sess[0])
        eng.submit(prompts[1], max_new_tokens=8, session=sess[1])
        eng.step()
        eng.step()                    # dirty pages resealed (fused write)
        assert eng.stats["fused_write_ticks"] >= 2
        s0, s1 = eng.slots[0], eng.slots[1]
        dirty0 = (s0.length - 1) // eng.page_tokens
        s1.pages[dirty0] = s0.pages[dirty0]
        s1.page_epochs[dirty0] = s0.page_epochs[dirty0]
        with pytest.raises(IntegrityError):
            eng.run()


class TestTenantScheduling:
    def test_quota_exceeded_admission_queues(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(1), max_tenants=2)
        reg.register("small", page_quota=3)
        reg.register("big")
        s_small = reg.open_session("small")
        s_big = reg.open_session("big")
        eng = _engine(smoke, max_slots=3, n_pages=12, registry=reg)
        a1 = eng.submit(prompts[0], max_new_tokens=4, session=s_small)
        a2 = eng.submit(prompts[0], max_new_tokens=4, session=s_small)
        b1 = eng.submit(prompts[1], max_new_tokens=6, session=s_big)
        done = eng.run()
        # Everyone finished, nobody was evicted for the quota: the
        # second small-tenant request simply waited its turn.
        assert set(done) == {a1, a2, b1}
        assert eng.stats["preemptions"] == 0
        assert done[a2].first_tick >= done[a1].done_tick
        # And over-quota single requests are rejected outright.
        with pytest.raises(ValueError):
            eng.submit(list(range(1, 10)), max_new_tokens=6,
                       session=s_small)

    def test_memory_pressure_evicts_tenant_scoped(self, smoke, prompts):
        # Tenant a: two growing requests (prompt 5, gen 10 -> up to 4
        # pages each).  Tenant b: one request whose admission
        # allocation (3 pages) already covers its whole decode, so b
        # never grows — any eviction of b would be collateral damage
        # from a's memory pressure, which tenant scoping forbids.
        p_a, p_b = prompts[0], prompts[0] + [7, 7, 7]

        def build(n_pages):
            reg = TenantRegistry(KeyHierarchy(2), max_tenants=2)
            reg.register("a")
            reg.register("b")
            sa, sb = (reg.open_session(t) for t in ("a", "b"))
            eng = _engine(smoke, max_slots=3, n_pages=n_pages, registry=reg)
            rids = [eng.submit(p_a, max_new_tokens=10, session=sa),
                    eng.submit(p_a, max_new_tokens=10, session=sa),
                    eng.submit(p_b, max_new_tokens=5, session=sb)]
            return eng, rids

        roomy, rids = build(12)
        want = [roomy.run()[r].generated for r in rids]
        assert roomy.stats["preemptions"] == 0

        tight, rids = build(7)
        done = tight.run()
        assert tight.stats["preemptions"] > 0
        # Tenant a's pressure only ever preempted tenant a's requests.
        assert done[rids[2]].n_evictions == 0
        assert done[rids[0]].n_evictions + done[rids[1]].n_evictions > 0
        assert [done[r].generated for r in rids] == want

    def test_weighted_fair_admission_favors_heavy_tenant(self, smoke,
                                                         prompts):
        reg = TenantRegistry(KeyHierarchy(4), max_tenants=2)
        reg.register("heavy", weight=4.0)
        reg.register("light", weight=1.0)
        sh = reg.open_session("heavy")
        sl = reg.open_session("light")
        eng = _engine(smoke, max_slots=1, n_pages=4, registry=reg)
        h = [eng.submit(prompts[0], max_new_tokens=3, session=sh)
             for _ in range(2)]
        li = [eng.submit(prompts[0], max_new_tokens=3, session=sl)
              for _ in range(2)]
        done = eng.run()
        # Both heavy requests are served before light's second one.
        assert max(done[r].first_tick for r in h) < \
            done[li[1]].first_tick

    def test_late_arriving_tenant_does_not_monopolize(self, smoke,
                                                      prompts):
        # WFQ no-credit-for-idle: after tenant a has run alone for a
        # while, a newly-arriving tenant b starts at the system virtual
        # time — admissions interleave instead of b draining its whole
        # backlog first.
        reg, (sa, sb) = _registry(2, seed=6)
        eng = _engine(smoke, max_slots=1, n_pages=4, registry=reg)
        for _ in range(2):                       # a runs alone first
            eng.submit(prompts[0], max_new_tokens=2, session=sa)
        eng.run()
        a3 = eng.submit(prompts[0], max_new_tokens=2, session=sa)
        eng.submit(prompts[0], max_new_tokens=2, session=sa)
        eng.submit(prompts[0], max_new_tokens=2, session=sb)
        b2 = eng.submit(prompts[0], max_new_tokens=2, session=sb)
        done = eng.run()
        assert done[a3].first_tick < done[b2].first_tick
