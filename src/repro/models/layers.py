"""Common model components + the ParamSpec infrastructure.

Every parameter is described by a :class:`ParamSpec` carrying its
shape, dtype and *logical axes* (MaxText-style).  Spec pytrees mirror
param pytrees, so:

  * the dry-run lowers against ``jax.ShapeDtypeStruct`` built straight
    from specs — a 671B model is never materialized;
  * the sharding planner maps logical axes -> mesh axes with
    divisibility checking (see :mod:`repro.launch.sharding`);
  * ``init_params`` materializes real (reduced-config) models for smoke
    tests, examples and CPU training.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "shape_structs", "rms_norm",
           "layer_norm", "rope", "dense", "DEFAULT_DTYPE"]

DEFAULT_DTYPE = "bfloat16"


class ParamSpec(NamedTuple):
    shape: tuple
    dtype: str
    axes: tuple            # logical axis names, len(axes) == len(shape)
    init: str = "fan_in"   # fan_in | zeros | ones | embed

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


def spec(shape, axes, dtype=DEFAULT_DTYPE, init="fan_in") -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), dtype, tuple(axes), init)


def _init_leaf(key, s: ParamSpec) -> jax.Array:
    dtype = jnp.dtype(s.dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "embed":
        # Tied-embedding-friendly scale (0.02, GPT-style): keeps initial
        # logits near zero so loss starts at ~ln(vocab).
        return (jax.random.normal(key, s.shape, jnp.float32) * 0.02
                ).astype(dtype)
    # fan_in: truncated-normal-ish scaled by 1/sqrt(fan_in); fan_in is
    # the product of all dims except the last.
    fan_in = max(1, math.prod(s.shape[:-1]))
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(dtype)


def init_params(specs: Any, key: jax.Array) -> Any:
    """Materialize a param pytree from a spec pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    params = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, params)


def shape_structs(specs: Any) -> Any:
    """Spec pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: s.struct(), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Numerics.  Norms run in f32 and cast back (standard practice).
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    out = ((xf - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
           + bias.astype(jnp.float32))
    return out.astype(dtype)


def rope(x: jax.Array, positions: jax.Array, *, base: float = 10000.0) -> jax.Array:
    """Rotary embedding on (..., seq, heads, head_dim)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(-math.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """Matmul with f32 accumulation (bf16 inputs, MXU-style)."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
