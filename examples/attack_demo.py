"""The paper's two attacks, live (Algorithms 1 and 2).

    PYTHONPATH=src python examples/attack_demo.py

SECA (Single-Element Collision Attack): recovers a whole encrypted
block when all 128-bit segments share one OTP — and fails against
SeDA's B-AES diversified pads.

RePA (Re-Permutation Attack): permutes ciphertext blocks under a naive
XOR-MAC layer check (Securator-style) without detection — and is caught
by SeDA's position-bound MACs.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import attacks, baes, mac
from repro.core.secure_memory import SecureKeys


def seca_demo(keys) -> None:
    print("--- SECA (Algorithm 1) ---")
    rng = np.random.default_rng(0)
    # A DNN-like data block: 8 segments, mostly zeros (ReLU sparsity).
    block = np.zeros((8, 16), np.uint8)
    block[2] = rng.integers(0, 256, 16, dtype=np.uint8)
    print(f"plaintext block: 8 segments, 7 zero (sparse fmap), 1 secret")
    flat = jnp.asarray(block.reshape(-1))
    cw = jnp.asarray([[0, 0, 0, 5]], dtype=jnp.uint32)

    ct = np.asarray(baes.shared_otp_encrypt(flat, keys.round_keys, cw,
                                            block_bytes=128))
    res = attacks.seca_recover_block(ct)
    print(f"[shared OTP]  modal ciphertext multiplicity="
          f"{res.collision_count}/8 -> OTP recovered; "
          f"plaintext recovered: {bool((res.recovered_plain == block).all())}")
    print(f"              secret segment recovered: "
          f"{bytes(res.recovered_plain[2]).hex()}")

    ct2 = np.asarray(baes.baes_encrypt(flat, keys.round_keys, cw,
                                       block_bytes=128, key=keys.key))
    res2 = attacks.seca_recover_block(ct2)
    print(f"[SeDA B-AES]  modal ciphertext multiplicity="
          f"{res2.collision_count}/8 (diversified pads) -> "
          f"plaintext recovered: "
          f"{bool((res2.recovered_plain == block).all())}")


def repa_demo(keys) -> None:
    print("\n--- RePA (Algorithm 2) ---")
    rng = np.random.default_rng(1)
    layer = jnp.asarray(rng.integers(0, 256, (16, 64), dtype=np.uint8))
    bind = mac.Binding.make(np.arange(16, dtype=np.uint32) * 4, 7, 3, 0,
                            np.arange(16, dtype=np.uint32))
    kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys)
    shuffled = jnp.asarray(attacks.repa_shuffle(np.asarray(layer), seed=3))
    print("attacker permutes the 16 ciphertext blocks of a layer")

    naive_before = mac.layer_mac(layer, bind, engine="naive", **kw)
    naive_after = mac.layer_mac(shuffled, bind, engine="naive", **kw)
    passed = bool((np.asarray(naive_before) == np.asarray(naive_after)).all())
    print(f"[naive XOR-MAC]    verification passes after shuffle: {passed} "
          f"(attack SUCCEEDS — model silently corrupted)")

    seda_before = mac.layer_mac(layer, bind, engine="nh", **kw)
    seda_after = mac.layer_mac(shuffled, bind, engine="nh", **kw)
    passed = bool((np.asarray(seda_before) == np.asarray(seda_after)).all())
    print(f"[SeDA bound MACs]  verification passes after shuffle: {passed} "
          f"(attack DEFEATED by (PA,VN,layer,fmap,blk) binding)")


if __name__ == "__main__":
    keys = SecureKeys.derive(7)
    seca_demo(keys)
    repa_demo(keys)
    print("\n=== attack_demo OK ===")
