"""MGX/TNPU-style on-chip version-number generation (paper §II-C, Tab. III).

Secure accelerators classically store one VN per protected block
off-chip (and a Merkle tree over the VNs).  MGX's observation — which
SeDA inherits — is that DNN memory access patterns are *deterministic
in the schedule*: the VN of any tensor crossing the boundary can be
derived on-chip from (tensor role, layer id, step counter), so no VN
ever needs to be stored or fetched.

For MoE models the routed expert *activations* are data-dependent, but
the schedule slot (step, layer, expert-slot) is not; using the slot as
the VN keeps generation on-chip (DESIGN.md §5 note).

``vn_for`` is pure and traceable; roles are small static ints.
"""

from __future__ import annotations

from enum import IntEnum

import jax.numpy as jnp

__all__ = ["Role", "vn_for", "vn_words", "kv_page_vn"]


class Role(IntEnum):
    WEIGHT = 0       # model weights: VN bumps on checkpoint/update epoch
    ACTIVATION = 1   # per-step intermediate fmaps
    KVCACHE = 2      # serving caches: VN bumps per decode step
    OPT_STATE = 3    # optimizer state (training)
    GRADIENT = 4
    DATA = 5         # input batches


def vn_for(role: Role | int, *, layer_id=0, step=0, slot=0) -> jnp.ndarray:
    """Deterministic 32-bit VN: role (3b) | layer (9b) | slot (8b) | step (12b).

    The bit budget is a policy choice, not a security parameter: the
    full counter fed to AES-CTR also contains the 64-bit PA, and the
    (role, layer, slot, step) tuple is unique per write within a
    training/serving session, which is what CTR requires.
    """
    role_u = jnp.uint32(int(role) & 0x7)
    layer_u = jnp.asarray(layer_id, jnp.uint32) & jnp.uint32(0x1FF)
    slot_u = jnp.asarray(slot, jnp.uint32) & jnp.uint32(0xFF)
    step_u = jnp.asarray(step, jnp.uint32) & jnp.uint32(0xFFF)
    return (role_u << 29) | (layer_u << 20) | (slot_u << 12) | step_u


def vn_words(role: Role | int, *, layer_id=0, step=0, slot=0):
    """(vn_hi, vn_lo) uint32 pair for counter construction."""
    lo = vn_for(role, layer_id=layer_id, step=step, slot=slot)
    return jnp.zeros_like(lo), lo


def kv_page_vn(write_epoch) -> jnp.ndarray:
    """VN for a KV-cache page: KVCACHE role tag | 29-bit write epoch.

    The serving engine's page pool bumps one global write epoch per
    protected write event (prefill or batched decode step), so the
    12-bit ``step`` field of :func:`vn_for` would wrap within a long
    decode.  Pages at different pool addresses share an epoch — CTR
    uniqueness comes from the (PA, VN) pair, and PA distinguishes them.
    """
    tag = jnp.uint32(int(Role.KVCACHE)) << jnp.uint32(29)
    return tag | (jnp.asarray(write_epoch, jnp.uint32)
                  & jnp.uint32((1 << 29) - 1))
