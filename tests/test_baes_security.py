"""B-AES (§III-B): bandwidth-aware encryption + SECA attack/defense."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import attacks, baes
from repro.core.secure_memory import SecureKeys


def _counters(n, vn=5):
    return jnp.asarray(
        np.stack([np.zeros(n, np.uint32), np.arange(n, dtype=np.uint32) * 32,
                  np.zeros(n, np.uint32), np.full(n, vn, np.uint32)], -1))


class TestBAES:
    @pytest.mark.parametrize("block_bytes", [32, 64, 128, 176, 512, 1024])
    def test_roundtrip_all_granularities(self, keys, rng, block_bytes):
        data = jnp.asarray(rng.integers(0, 256, block_bytes * 7,
                                        dtype=np.uint8))
        cw = _counters(7)
        enc = baes.baes_encrypt(data, keys.round_keys, cw,
                                block_bytes=block_bytes, key=keys.key)
        dec = baes.baes_decrypt(enc, keys.round_keys, cw,
                                block_bytes=block_bytes, key=keys.key)
        assert (np.asarray(dec) == np.asarray(data)).all()

    @pytest.mark.parametrize("n_segments", [2, 4, 8, 11, 16, 32, 64])
    def test_segment_otps_all_distinct(self, keys, n_segments):
        otps = np.asarray(baes.baes_otps(keys.round_keys, _counters(3),
                                         n_segments=n_segments, key=keys.key))
        for blk in otps:
            assert len({bytes(o) for o in blk}) == n_segments

    def test_one_aes_invocation_worth_of_structure(self, keys):
        """Narrow-mode pads differ from the base OTP by round keys only."""
        otps = np.asarray(baes.baes_otps(keys.round_keys, _counters(1),
                                         n_segments=4))
        base = otps[0, 0]
        rks = np.asarray(keys.round_keys)
        for i in range(1, 4):
            assert (otps[0, i] == (base ^ rks[i])).all()

    def test_blocks_get_distinct_base_otps(self, keys):
        otps = np.asarray(baes.baes_otps(keys.round_keys, _counters(5),
                                         n_segments=4))
        assert len({bytes(o) for o in otps[:, 0]}) == 5


class TestSECA:
    """Algorithm 1: attack shared-OTP, defense with B-AES."""

    def _sparse_block(self, rng, n_segments=8):
        # DNN-like block: mostly zeros (ReLU sparsity) + one hot segment.
        block = np.zeros((n_segments, 16), np.uint8)
        block[2] = rng.integers(0, 256, 16, dtype=np.uint8)
        return block

    def test_seca_succeeds_against_shared_otp(self, keys, rng):
        block = self._sparse_block(rng)
        flat = jnp.asarray(block.reshape(-1))
        ct = np.asarray(baes.shared_otp_encrypt(
            flat, keys.round_keys, _counters(1), block_bytes=128))
        res = attacks.seca_recover_block(ct)
        assert (res.recovered_plain == block).all()
        assert res.collision_count >= 6  # the zero segments collide

    def test_seca_fails_against_baes(self, keys, rng):
        block = self._sparse_block(rng)
        flat = jnp.asarray(block.reshape(-1))
        ct = np.asarray(baes.baes_encrypt(flat, keys.round_keys, _counters(1),
                                          block_bytes=128, key=keys.key))
        res = attacks.seca_recover_block(ct)
        assert not (res.recovered_plain == block).all()
        assert res.collision_count == 1  # diversified pads: no collisions

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000))
    def test_seca_defense_property(self, seed):
        """For any sparse plaintext, B-AES ciphertext segments never
        collide (distinct pads), removing SECA's signal."""
        keys = SecureKeys.derive(99)
        rng = np.random.default_rng(seed)
        block = np.zeros((8, 16), np.uint8)
        block[rng.integers(0, 8)] = rng.integers(0, 256, 16, dtype=np.uint8)
        ct = np.asarray(baes.baes_encrypt(
            jnp.asarray(block.reshape(-1)), keys.round_keys,
            _counters(1, vn=seed), block_bytes=128, key=keys.key))
        segs = ct.reshape(8, 16)
        assert len({bytes(s) for s in segs}) == 8
