"""Cross-PR bench trajectory: normalize bench JSONs into history rows.

Every benchmark in this directory writes a ``stamp()``-ed JSON
artifact per run.  Those are point-in-time: nothing connected run N to
run N-1, so a perf regression only showed up if someone diffed two CI
artifacts by hand.  This module gives the repo performance *memory*:

    python benchmarks/history.py --history BENCH_history.jsonl \\
        bench-*.json

appends one normalized row per (benchmark, scheme, config) result to
``BENCH_history.jsonl`` — an append-only JSON-lines file that is
committed to the repo and re-appended by every CI perf-smoke run.
``check_regression.py`` reads it back as the baseline set.

Row shape (one JSON object per line)::

    {"benchmark": "secure_serving", "scheme": "seda",
     "config": "batch=8",                      # stable key, sorted k=v
     "metrics": {"tok_per_s": 1234.5, "traffic_overhead": 0.11},
     "git_sha": "...", "git_dirty": false, "host": "Linux-x86_64",
     "timestamp_utc": "..."}

Config keys are whitelisted (:data:`CONFIG_KEYS`) so incidental row
fields (latency dicts, counters) never fragment the baseline key.
"""

from __future__ import annotations

import argparse
import json
import os
import re

__all__ = ["CONFIG_KEYS", "METRIC_KEYS", "normalize", "append_history",
           "load_history"]

# Fields that identify *which* experiment a row is (part of the key).
CONFIG_KEYS = ("batch", "shards", "tenants", "rotate_every", "hit_rate",
               "context_len", "gen_len", "name", "mode", "bucket")

# Fields that are *measurements* (compared by check_regression.py).
# True = higher is better, False = lower is better.
METRIC_KEYS = {
    "tok_per_s": True,
    "tok_per_s_off": True,
    "tok_per_s_on": True,
    "us_per_call": False,
    "us_per_step": False,
    "traffic_overhead": False,
    "overhead_pct": False,
    "overhead_bytes_ratio": False,
    "overhead_flops_ratio": False,
}

_SCHEME_IN_NAME = re.compile(
    r"_(off|sgx64|sgx512|mgx64|mgx512|seda512|seda)(_|$)")


def _row_scheme(result: dict) -> str:
    scheme = result.get("scheme")
    if scheme:
        return str(scheme)
    m = _SCHEME_IN_NAME.search(str(result.get("name", "")))
    return m.group(1) if m else "unknown"


def _config_key(result: dict) -> str:
    parts = []
    for k in CONFIG_KEYS:
        if k in result and result[k] is not None:
            parts.append(f"{k}={result[k]}")
    return ",".join(parts)


def normalize(payload: dict) -> list:
    """One bench JSON (``{"benchmark", "results", "meta"}``) to rows."""
    meta = payload.get("meta", {})
    rows = []
    for result in payload.get("results", []):
        metrics = {k: float(result[k]) for k in METRIC_KEYS
                   if k in result and result[k] is not None}
        if not metrics:
            continue
        rows.append({
            "benchmark": payload.get("benchmark", "unknown"),
            "scheme": _row_scheme(result),
            "config": _config_key(result),
            "metrics": metrics,
            "git_sha": meta.get("git_sha", "unknown"),
            "git_dirty": bool(meta.get("git_dirty", True)),
            "host": meta.get("host", "unknown"),
            "timestamp_utc": meta.get("timestamp_utc", ""),
        })
    return rows


def append_history(history_path: str, payloads: list) -> int:
    """Append normalized rows for each bench payload; returns count."""
    rows = []
    for payload in payloads:
        rows.extend(normalize(payload))
    if rows:
        with open(history_path, "a") as f:
            for row in rows:
                f.write(json.dumps(row, sort_keys=True) + "\n")
    return len(rows)


def load_history(history_path: str) -> list:
    """Parse the JSONL history (missing file -> empty; bad lines are
    skipped so one corrupt append can never brick the gate)."""
    if not os.path.exists(history_path):
        return []
    rows = []
    with open(history_path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(row, dict) and "metrics" in row:
                rows.append(row)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsons", nargs="+", help="bench JSON artifacts")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    args = ap.parse_args(argv)
    payloads = []
    for path in args.jsons:
        with open(path) as f:
            payloads.append(json.load(f))
    n = append_history(args.history, payloads)
    print(f"[history] appended {n} rows from {len(args.jsons)} bench "
          f"files to {args.history}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
