"""Serving driver: load a SeDA-secured checkpoint and decode batches.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --smoke --ckpt-dir /tmp/ck --prompt-len 16 --gen-len 16 --batch 4

Weights restore ONLY if their layer MACs verify (tampered checkpoints
are refused); the deferred model-MAC check runs after the generation
loop (paper Table I semantics).

``--engine paged`` serves through the continuous-batching secure
engine instead: the KV cache lives as a paged, MAC-protected pool
(page size = the scheme's optBlk granularity multiple), decode steps
verify only touched pages and re-MAC only dirty ones::

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --smoke --engine paged --scheme seda --batch 8 --gen-len 16

``--tenants N`` registers N tenants in a key-management registry and
serves the batch round-robin across their sessions: every tenant's KV
pages live under its own (tenant, epoch) keys from the hierarchical
KDF, with weighted-fair admission and tenant-scoped eviction.
``--rotate-every K`` additionally rotates one tenant's keys every K
scheduler ticks (round-robin), exercising live lazy rotation::

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --smoke --engine paged --scheme seda --batch 8 --gen-len 16 \
        --tenants 4 --rotate-every 8

``--shards N`` serves through the cluster engine instead: one shard
engine (and one paged pool, shard-bound RePA/CTR identity included)
per device, least-loaded routing with tenant affinity, and secure page
migration under imbalance.  On CPU the N devices are conjured via
``--xla_force_host_platform_device_count`` (set below, before jax
initializes)::

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --smoke --engine paged --scheme seda --batch 8 --gen-len 16 \
        --shards 2
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import sys
import time

# Must run before jax initializes its backends: a --shards run on a
# single-device host forces that many CPU devices into existence.
# Both argparse spellings (--shards N and --shards=N) must match here.
def _sniff_shards(argv) -> int:
    n = 1
    for i, arg in enumerate(argv):
        val = None
        if arg == "--shards" and i + 1 < len(argv):
            val = argv[i + 1]
        elif arg.startswith("--shards="):
            val = arg.split("=", 1)[1]
        if val is not None:
            try:
                n = int(val)
            except ValueError:
                pass
    return n


_n = _sniff_shards(sys.argv)
if _n > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.checkpoint.secure_ckpt import latest_step, load_checkpoint  # noqa: E402
from repro.configs import get_arch                     # noqa: E402
from repro.core.secure_memory import SecureKeys        # noqa: E402
from repro.models import lm as lm_mod                  # noqa: E402
from repro.models.layers import init_params, shape_structs  # noqa: E402
from repro.serve.serve_step import (greedy_sample, make_decode_step,  # noqa: E402
                                    make_prefill_step)

log = logging.getLogger("repro.serve")


class _JsonFormatter(logging.Formatter):
    """One JSON object per record: ts/level/event/msg + extra fields."""

    def format(self, record: logging.LogRecord) -> str:
        doc = {"ts": round(record.created, 3),
               "level": record.levelname.lower(),
               "event": getattr(record, "event", "message"),
               "msg": record.getMessage()}
        doc.update(getattr(record, "fields", None) or {})
        return json.dumps(doc, sort_keys=True)


def _setup_logging(args) -> None:
    """Route CLI output through the ``repro.serve`` logger.

    Default: plain messages on stdout, character-identical to the old
    ``print`` output.  ``--json-logs`` swaps in one structured JSON
    record per line; ``--quiet`` drops everything below WARNING.
    """
    log.handlers.clear()
    handler = logging.StreamHandler(sys.stdout)
    handler.setFormatter(_JsonFormatter() if args.json_logs
                         else logging.Formatter("%(message)s"))
    log.addHandler(handler)
    log.setLevel(logging.WARNING if args.quiet else logging.INFO)
    log.propagate = False


def _log(event: str, msg: str, **fields) -> None:
    log.info(msg, extra={"event": event, "fields": fields})


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--engine", choices=("simple", "paged"), default="simple")
    ap.add_argument("--scheme", default="seda",
                    help="protection scheme for --engine paged")
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=0,
                    help="0 = sized from prompt+gen length")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="0 = batch * pages_per_slot")
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve through N per-tenant key domains "
                         "(--engine paged only; 0 = single-tenant)")
    ap.add_argument("--rotate-every", type=int, default=0,
                    help="rotate one tenant's keys every K ticks "
                         "(round-robin; needs --tenants)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve through an N-shard cluster engine, one "
                         "paged pool per device (--engine paged only; "
                         "0 = single shard engine)")
    ap.add_argument("--fault-tolerance", action="store_true",
                    help="contain integrity faults instead of aborting: "
                         "quarantine failing pages, recover sessions by "
                         "secure recompute, fail over compromised shards "
                         "(--engine paged only)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress informational output")
    ap.add_argument("--json-logs", action="store_true",
                    help="one structured JSON record per log line")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tick-phase tracing; write Chrome "
                         "trace-event JSON here (--engine paged only)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a metrics snapshot (JSON) after the run")
    ap.add_argument("--metrics-prom", default=None, metavar="PATH",
                    help="write Prometheus text exposition after the run")
    ap.add_argument("--audit-proof-out", default=None, metavar="PATH",
                    help="capture one Merkle membership proof per live "
                         "session after the first tick, verify each "
                         "host-independently, and write the bundle plus "
                         "the final (cluster) root here as JSON "
                         "(--engine paged only)")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="enable the hash-chained audit log; dump it "
                         "here as JSON lines (--engine paged only)")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="per-tenant wall-clock ttft SLO target in ms; "
                         "breaches are counted + audited "
                         "(--engine paged only)")
    ap.add_argument("--slo-p99-ticks", type=float, default=0.0,
                    help="rolling p99 tick-latency SLO target in ms "
                         "(--engine paged only)")
    ap.add_argument("--http-port", type=int, default=0,
                    help="serve /healthz (SLO health JSON) and /metrics "
                         "(Prometheus text) on 127.0.0.1:PORT during "
                         "the run (--engine paged only)")
    ap.add_argument("--profile-json", default=None, metavar="PATH",
                    help="write the protection-vs-model device-cost "
                         "profile (obs/profiler.py) here after the run "
                         "(--engine paged only; compiles one decode "
                         "variant per bucket)")
    args = ap.parse_args(argv)
    _setup_logging(args)
    if args.tenants and args.engine != "paged":
        raise SystemExit("--tenants needs --engine paged")
    if args.shards and args.engine != "paged":
        raise SystemExit("--shards needs --engine paged")
    if args.rotate_every and not args.tenants:
        raise SystemExit("--rotate-every needs --tenants (there are no "
                         "tenant keys to rotate otherwise)")
    if args.engine != "paged" and (args.trace_out or args.metrics_json
                                   or args.metrics_prom or args.audit_out
                                   or args.audit_proof_out
                                   or args.slo_ttft_ms or args.slo_p99_ticks
                                   or args.http_port or args.profile_json
                                   or args.fault_tolerance):
        raise SystemExit("--trace-out/--metrics-json/--metrics-prom/"
                         "--audit-out/--audit-proof-out/--slo-*/"
                         "--http-port/--profile-json/"
                         "--fault-tolerance need --engine paged (the "
                         "simple loop has no observability surface)")

    arch = get_arch(args.arch)
    if arch.kind == "encdec":
        raise SystemExit("use the decoder demo in examples/ for enc-dec")
    cfg = arch.make_smoke_config() if args.smoke else arch.make_config()
    specs = lm_mod.lm_specs(cfg)
    keys = SecureKeys.derive(args.seed)

    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        step = latest_step(args.ckpt_dir)
        path = os.path.join(args.ckpt_dir, f"step_{step:08d}")
        params, _ = load_checkpoint(path, shape_structs(specs), keys)
        _log("checkpoint", f"[serve] loaded + verified checkpoint {path}",
             path=path)
    else:
        params = init_params(specs, jax.random.PRNGKey(args.seed))
        _log("checkpoint", "[serve] no checkpoint: serving fresh init")

    if args.engine == "paged":
        return _serve_paged(arch, cfg, params, args)

    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(make_prefill_step(arch, cfg, max_len))
    decode = jax.jit(make_decode_step(arch, cfg))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        1, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int64)
        .astype(np.int32))
    logits, caches = prefill(params, {"tokens": prompts})
    tok = greedy_sample(logits)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        logits, caches = decode(params, tok, caches)
        tok = greedy_sample(logits)
        out.append(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    rate = args.batch * args.gen_len / max(dt, 1e-9)
    _log("summary", f"[serve] {args.gen_len} tokens x {args.batch} requests "
         f"({rate:.1f} tok/s)",
         gen_len=args.gen_len, batch=args.batch, tok_per_s=rate)
    return {"tokens": np.asarray(toks), "tok_per_s": rate}


def _serve_paged(arch, cfg, params, args) -> dict:
    """Continuous-batching path: paged, MAC-protected KV pool."""
    from repro.serve.engine import SecureServingEngine

    pages_per_slot = args.pages_per_slot or -(
        -(args.prompt_len + args.gen_len) // args.page_tokens)
    n_pages = args.n_pages or args.batch * pages_per_slot
    registry = None
    sessions = []
    if args.tenants:
        from repro.tenancy import KeyHierarchy, TenantRegistry
        registry = TenantRegistry(KeyHierarchy(args.seed),
                                  max_tenants=args.tenants)
        for t in range(args.tenants):
            registry.register(f"tenant-{t}")
            sessions.append(registry.open_session(f"tenant-{t}"))
    obs_kw = dict(trace=bool(args.trace_out), audit=bool(args.audit_out))
    ft = True if args.fault_tolerance else None
    if args.shards:
        from repro.serve.cluster import ClusterEngine
        per_shard = -(-args.batch // args.shards)
        eng = ClusterEngine(
            arch, cfg, params, shards=args.shards, scheme=args.scheme,
            max_slots=per_shard, page_tokens=args.page_tokens,
            pages_per_slot=pages_per_slot,
            n_pages=-(-n_pages // args.shards),
            keys=SecureKeys.derive(args.seed),
            registry=registry, rotate_every=args.rotate_every,
            fault_tolerance=ft, **obs_kw)
        stats_of = lambda: dict(eng.engine_stats, **eng.stats)  # noqa: E731
    else:
        eng = SecureServingEngine(
            arch, cfg, params, scheme=args.scheme, max_slots=args.batch,
            page_tokens=args.page_tokens, pages_per_slot=pages_per_slot,
            n_pages=n_pages, keys=SecureKeys.derive(args.seed),
            registry=registry, rotate_every=args.rotate_every,
            fault_tolerance=ft, **obs_kw)
        stats_of = lambda: eng.stats  # noqa: E731

    # SLO watchdogs: one monitor per shard engine; /healthz reports the
    # worst shard.  Without targets (and without --http-port) nothing
    # attaches, so the hot path stays untouched.
    monitors = []
    if args.slo_ttft_ms or args.slo_p99_ticks or args.http_port:
        from repro.obs.slo import SLOMonitor
        for shard_eng in (eng.engines if args.shards else [eng]):
            monitors.append(SLOMonitor(
                ttft_ms=args.slo_ttft_ms or None,
                p99_tick_ms=args.slo_p99_ticks or None,
                min_stall_s=1.0).attach(shard_eng))
    server = None
    if args.http_port:
        server = _start_http(args.http_port, monitors, eng)
        _log("http", f"[serve] /healthz + /metrics on "
             f"127.0.0.1:{args.http_port}", port=args.http_port)

    rng = np.random.default_rng(args.seed)
    rids = []
    for i in range(args.batch):
        prompt = list(map(int, rng.integers(1, cfg.vocab, args.prompt_len)))
        session = sessions[i % len(sessions)] if sessions else None
        rids.append(eng.submit(prompt=prompt, max_new_tokens=args.gen_len,
                               session=session))
    proof_bundle = None
    if args.audit_proof_out:
        # One tick admits the batch; every session is then resident and
        # can prove membership against the live Merkle root — the
        # verification below is exactly what a tenant runs, keyless.
        eng.step()
        proof_bundle = _capture_audit_proofs(eng, sessions,
                                             bool(args.shards))
        _log("audit-proof", f"[serve] {proof_bundle['verified']} session "
             f"proofs captured + verified at tick {proof_bundle['tick']}",
             tick=proof_bundle["tick"], proofs=proof_bundle["verified"])
    t0 = time.perf_counter()
    done, sig = _run_graceful(eng, is_cluster=bool(args.shards))
    dt = time.perf_counter() - t0
    if sig is not None:
        n_done = sum(1 for r in rids
                     if eng.requests[r].state == "finished")
        _log("shutdown", f"[serve] signal {sig}: graceful shutdown after "
             f"tick {eng.tick} ({n_done}/{args.batch} requests finished); "
             f"flushing observability artifacts",
             signal=int(sig), tick=eng.tick, finished=n_done,
             requests=args.batch)
    n_tokens = sum(len(eng.requests[r].generated) for r in rids)
    rate = n_tokens / max(dt, 1e-9)
    stats = stats_of()
    mode = f"paged/{args.scheme}" + (
        f"/{args.tenants} tenants" if args.tenants else "") + (
        f"/{args.shards} shards" if args.shards else "")
    extra = (f", {stats['migrations']} migrations" if args.shards else "")
    mac_ok = eng.deferred_check()
    _log("summary", f"[serve] {mode}: {n_tokens} tokens over "
         f"{args.batch} requests ({rate:.1f} tok/s incl. compile), "
         f"{stats['preemptions']} preemptions, "
         f"{stats['rotations']} key rotations{extra}, "
         f"deferred {'root' if args.shards else 'pool'} MAC "
         f"{'OK' if mac_ok else 'FAIL'}",
         mode=mode, tokens=n_tokens, requests=args.batch, tok_per_s=rate,
         ticks=eng.tick, stats=dict(stats), deferred_mac_ok=bool(mac_ok))
    if done.latency:
        _log("latency", f"[serve] latency (ticks): "
             f"ttft p50={done.latency['p50_ttft_ticks']:.1f} "
             f"p95={done.latency['p95_ttft_ticks']:.1f} "
             f"p99={done.latency['p99_ttft_ticks']:.1f}",
             **done.latency)
    # Final stall poll *now*, before the obs dumps: profiling compiles
    # for seconds, and idle time after the run finished is not a stall.
    for m in monitors:
        m.check_stalled()
    _dump_obs(eng, args)
    if args.audit_proof_out:
        _dump_audit_proofs(eng, args, proof_bundle)
    if monitors:
        from repro.obs.slo import merge_health
        health = merge_health([m.health() for m in monitors])
        _log("slo", f"[serve] SLO health: {health['status']}",
             **{"health": health})
    if server is not None:
        server.shutdown()
    if sig is None and all(eng.requests[r].state == "finished"
                           for r in rids):
        toks = np.asarray([done[r].generated for r in rids], np.int32)
    else:
        # Interrupted (or fault-tolerant with lost sessions): per-
        # request emission lengths are ragged.
        toks = [list(map(int, eng.requests[r].generated)) for r in rids]
    if any(m.hard_breach for m in monitors):
        _log("slo", "[serve] hard SLO breach (integrity alarm or stuck "
             "tick) — exiting non-zero")
        raise SystemExit(3)
    return {"tokens": toks, "tok_per_s": rate, "stats": stats,
            "latency": done.latency}


def _run_graceful(eng, *, is_cluster: bool):
    """Drive the engine tick-by-tick so SIGINT/SIGTERM drain cleanly.

    A signal only sets a flag: the in-flight tick always finishes (no
    torn pool state, audit chain stays intact), the loop exits before
    the next one, and the caller flushes artifacts and applies the
    normal SLO exit-code discipline on the partial result.  Returns
    ``(result, signum-or-None)``; prior handlers are restored."""
    import signal

    from repro.serve.engine import RunResult, latency_percentiles

    got: list = []
    prev = {}
    for s in (signal.SIGINT, signal.SIGTERM):
        try:
            prev[s] = signal.signal(s, lambda signum, frame:
                                    got.append(signum))
        except ValueError:  # pragma: no cover - not the main thread
            pass

    def busy() -> bool:
        if is_cluster:
            return eng._busy()
        return bool(eng._n_waiting()
                    or any(s is not None for s in eng.slots))

    try:
        for _ in range(100_000):
            if not busy() or got:
                break
            eng.step()
        else:
            raise RuntimeError("serve loop exceeded max_ticks")
        if got:
            result = RunResult(
                {rid: req for rid, req in eng.requests.items()
                 if req.state == "finished"})
            result.latency = latency_percentiles(eng.requests.values())
            return result, got[0]
        # Drained: run() performs the end-of-run deferred checks (and,
        # under fault tolerance, keeps ticking if containment requeued
        # work) and builds the result exactly as before.
        return eng.run(), None
    finally:
        for s, h in prev.items():
            signal.signal(s, h)


def _start_http(port: int, monitors: list, eng):
    """Stdlib /healthz + /metrics endpoint on localhost, daemon thread.

    /healthz returns the merged monitor health (HTTP 503 once any
    shard is *failing* — integrity alarm or stuck tick — so probes can
    pull the instance); /metrics returns the Prometheus exposition of
    the engine (cluster: all shards, ``shard=`` labels).
    """
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    from repro.obs.slo import merge_health

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - http.server API
            if self.path.split("?")[0] == "/healthz":
                for m in monitors:
                    m.check_stalled()
                doc = merge_health([m.health() for m in monitors])
                code = 503 if doc["status"] == "failing" else 200
                body = json.dumps(doc, indent=2, sort_keys=True).encode()
                ctype = "application/json"
            elif self.path.split("?")[0] == "/metrics":
                body = eng.prometheus().encode()
                code, ctype = 200, "text/plain; version=0.0.4"
            else:
                body, code, ctype = b"not found\n", 404, "text/plain"
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # noqa: D102 - quiet by default
            pass

    server = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server


def _capture_audit_proofs(eng, sessions, is_cluster: bool) -> dict:
    """Audit proofs for every live session, tenant-verified in place."""
    from repro.serve import merkle_pool as mkp
    proofs = []
    for session in (sessions or [None]):
        got = eng.audit_proof(session)
        proofs.extend(got if is_cluster else [got])
    for p in proofs:
        mkp.verify_proof(p, expected_root=p.root, tenant=p.tenant)
    return {"tick": eng.tick, "verified": len(proofs),
            "proofs": [p.to_dict() for p in proofs]}


def _dump_audit_proofs(eng, args, bundle) -> None:
    """Write the captured proof bundle + the final attested root(s)."""
    from repro.serve import merkle_pool as mkp
    if args.shards:
        pairs = eng.sharded.merkle_roots()
        final = {"cluster_root": mkp.compress_roots(pairs).hex(),
                 "shard_roots": [[s, r.hex()] for s, r in pairs]}
    else:
        final = {"root": eng.merkle.root_hex()}
    payload = dict(bundle or {"tick": eng.tick, "verified": 0,
                              "proofs": []})
    payload["final"] = final
    with open(args.audit_proof_out, "w") as f:
        json.dump(payload, f, indent=1)
    _log("audit-proof", f"[serve] audit-proof bundle "
         f"({len(payload['proofs'])} proofs) -> {args.audit_proof_out}",
         path=args.audit_proof_out, proofs=len(payload["proofs"]))


def _dump_obs(eng, args) -> None:
    """Write the requested observability artifacts after a paged run."""
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(eng.snapshot(), f, indent=2, sort_keys=True)
        _log("metrics", f"[serve] metrics snapshot -> {args.metrics_json}",
             path=args.metrics_json)
    if args.metrics_prom:
        with open(args.metrics_prom, "w") as f:
            f.write(eng.prometheus())
        _log("metrics", f"[serve] prometheus text -> {args.metrics_prom}",
             path=args.metrics_prom)
    if args.trace_out:
        doc = eng.export_trace(args.trace_out)
        _log("trace", f"[serve] {len(doc['traceEvents'])} trace events -> "
             f"{args.trace_out}",
             path=args.trace_out, events=len(doc["traceEvents"]))
    if args.profile_json:
        doc = eng.profile()
        with open(args.profile_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        _log("profile", f"[serve] device-cost profile -> "
             f"{args.profile_json}", path=args.profile_json)
    if args.audit_out:
        eng.audit.dump(args.audit_out)
        _log("audit", f"[serve] {len(eng.audit)} audit records "
             f"(chain {'OK' if eng.audit.verify_chain() else 'BROKEN'}) -> "
             f"{args.audit_out}",
             path=args.audit_out, records=len(eng.audit),
             chain_ok=eng.audit.verify_chain())


if __name__ == "__main__":
    main()
