"""Shared helpers for the SeDA Pallas TPU kernels."""

from __future__ import annotations

import jax

__all__ = ["default_interpret", "cdiv"]


def default_interpret() -> bool:
    """Pallas interpret mode: True off-TPU (this container is CPU-only).

    Kernels TARGET TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
    validated in interpret mode, which executes the kernel body on CPU.
    """
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
