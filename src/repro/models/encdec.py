"""Encoder-decoder transformer backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_src, d_model).  The
backbone is real: a bidirectional encoder stack and a causal decoder
stack with cross-attention, sharing the block machinery.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.layers import ParamSpec, rms_norm, rope, spec
from repro.models.partitioning import constrain

__all__ = ["EncDecConfig", "encdec_specs", "encdec_forward", "encdec_loss",
           "encode", "decoder_prefill", "decoder_decode", "decoder_cache_specs"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    enc_layers: int
    dec_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0
    dtype: str = "bfloat16"
    q_block: int = 512
    kv_block: int = 1024
    remat: str = "full"

    def __post_init__(self):
        if not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)


def _stack(specs: Any, steps: int) -> Any:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((steps,) + s.shape, s.dtype, ("layers",) + s.axes,
                            s.init),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _enc_layer_specs(cfg: EncDecConfig):
    return {
        "norm_attn": spec((cfg.d_model,), ("embed",), "float32", init="ones"),
        "attn": attn_mod.attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                         cfg.head_dim, cfg.dtype),
        "norm_ffn": spec((cfg.d_model,), ("embed",), "float32", init="ones"),
        "ffn": moe_mod.ffn_specs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _dec_layer_specs(cfg: EncDecConfig):
    s = _enc_layer_specs(cfg)
    s["norm_cross"] = spec((cfg.d_model,), ("embed",), "float32", init="ones")
    s["cross"] = attn_mod.attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                          cfg.head_dim, cfg.dtype)
    return s


def encdec_specs(cfg: EncDecConfig) -> dict:
    return {
        "embed": spec((cfg.vocab, cfg.d_model), ("vocab", "embed"), cfg.dtype,
                      init="embed"),
        "enc_final_norm": spec((cfg.d_model,), ("embed",), "float32",
                               init="ones"),
        "dec_final_norm": spec((cfg.d_model,), ("embed",), "float32",
                               init="ones"),
        "encoder": _stack(_enc_layer_specs(cfg), cfg.enc_layers),
        "decoder": _stack(_dec_layer_specs(cfg), cfg.dec_layers),
    }


def _bidir_attention(cfg, params, x, positions):
    """Non-causal self-attention (full pairs) for the encoder."""
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    q, k = rope(q, positions), rope(k, positions)
    ctx = _full_attention(q, k, v)
    return jnp.einsum("blhk,hkd->bld", ctx, params["wo"])


def _full_attention(q, k, v, mask=None):
    """Unmasked (or masked) softmax attention with GQA broadcast."""
    b, lq, h, d = q.shape
    hkv = k.shape[2]
    groups = h // hkv
    scale = 1.0 / (d ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(b, lq, hkv, groups, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return ctx.reshape(b, lq, h, d).astype(q.dtype)


def _cross_attention(cfg, params, x, enc_kv, positions_q):
    """Decoder->encoder attention; enc_kv = (k, v) precomputed."""
    k, v = enc_kv
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    q = rope(q, positions_q)
    ctx = _full_attention(q, k, v)
    return jnp.einsum("blhk,hkd->bld", ctx, params["wo"])


def _cross_kv(params, enc_out, positions_src):
    k = jnp.einsum("bld,dhk->blhk", enc_out, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", enc_out, params["wv"])
    return rope(k, positions_src), v


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def encode(cfg: EncDecConfig, params, src_embeds):
    """src_embeds: (B, S, d_model) frame embeddings (frontend stub)."""
    b, s, _ = src_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = constrain(src_embeds.astype(jnp.dtype(cfg.dtype)),
                  "batch", "seq", "residual")

    def body(x, layer):
        h = rms_norm(x, layer["norm_attn"])
        x = x + _bidir_attention(cfg, layer["attn"], h, positions)
        h = rms_norm(x, layer["norm_ffn"])
        x = x + moe_mod.dense_ffn(layer["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"])


def _decoder_stack(cfg, params, x, positions, enc_out, positions_src):
    def body(x, layer):
        h = rms_norm(x, layer["norm_attn"])
        x = x + attn_mod.attention(layer["attn"], h, positions,
                                   q_block=cfg.q_block, kv_block=cfg.kv_block)
        h = rms_norm(x, layer["norm_cross"])
        enc_kv = _cross_kv(layer["cross"], enc_out, positions_src)
        x = x + _cross_attention(cfg, layer["cross"], h, enc_kv, positions)
        h = rms_norm(x, layer["norm_ffn"])
        x = x + moe_mod.dense_ffn(layer["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["decoder"])
    return rms_norm(x, params["dec_final_norm"])


def encdec_forward(cfg: EncDecConfig, params, batch):
    """batch: src_embeds (B,S,d), tgt_tokens (B,T).  Returns logits."""
    enc_out = encode(cfg, params, batch["src_embeds"])
    b, s = enc_out.shape[:2]
    positions_src = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    tgt = batch["tgt_tokens"]
    x = jnp.take(params["embed"], tgt, axis=0)
    x = constrain(x, "batch", "seq", "residual")
    t = tgt.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = _decoder_stack(cfg, params, x, positions, enc_out, positions_src)
    logits = jnp.einsum("bld,vd->blv", x, params["embed"])
    return constrain(logits, "batch", "seq", "vocab")


def encdec_loss(cfg: EncDecConfig, params, batch):
    logits = encdec_forward(cfg, params, batch).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    loss = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"nll": loss}


# ---------------------------------------------------------------------------
# Serving: decoder self-attn KV cache + cached cross K/V.
# ---------------------------------------------------------------------------


def decoder_cache_specs(cfg: EncDecConfig, batch: int, max_len: int,
                        src_len: int):
    self_cache = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.dec_layers,) + s.shape, s.dtype),
        attn_mod.init_kv_cache_specs(batch, max_len, cfg.n_kv, cfg.head_dim,
                                     cfg.dtype))
    cross_k = jax.ShapeDtypeStruct(
        (cfg.dec_layers, batch, src_len, cfg.n_kv, cfg.head_dim),
        jnp.dtype(cfg.dtype))
    return {"self": self_cache, "cross_k": cross_k, "cross_v": cross_k}


def decoder_cache_axes(cfg: EncDecConfig):
    """Logical axes mirroring decoder_cache_specs."""
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {
        "self": attn_mod.KVCache(k=kv, v=kv, length=("layers",)),
        "cross_k": kv, "cross_v": kv,
    }


def decoder_prefill(cfg: EncDecConfig, params, batch, max_len: int):
    """Encode src + run decoder over prompt, building caches."""
    enc_out = encode(cfg, params, batch["src_embeds"])
    b, s = enc_out.shape[:2]
    positions_src = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    tgt = batch["tgt_tokens"]
    t = tgt.shape[1]
    x = jnp.take(params["embed"], tgt, axis=0)
    x = constrain(x, "batch", "seq", "residual")
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    length = jnp.asarray(t, jnp.int32)

    def body(x, layer):
        h = rms_norm(x, layer["norm_attn"])
        out, (k, v) = attn_mod.attention(layer["attn"], h, positions,
                                         q_block=cfg.q_block,
                                         kv_block=cfg.kv_block, return_kv=True)
        x = x + out
        pad = [(0, 0), (0, max_len - t), (0, 0), (0, 0)]
        cache = attn_mod.KVCache(
            jnp.pad(k.astype(jnp.dtype(cfg.dtype)), pad),
            jnp.pad(v.astype(jnp.dtype(cfg.dtype)), pad), length)
        ck, cv = _cross_kv(layer["cross"], enc_out, positions_src)
        h = rms_norm(x, layer["norm_cross"])
        x = x + _cross_attention(cfg, layer["cross"], h, (ck, cv), positions)
        h = rms_norm(x, layer["norm_ffn"])
        x = x + moe_mod.dense_ffn(layer["ffn"], h)
        return x, (cache, ck.astype(jnp.dtype(cfg.dtype)),
                   cv.astype(jnp.dtype(cfg.dtype)))

    x, (self_cache, cross_k, cross_v) = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["dec_final_norm"])
    logits = jnp.einsum("bld,vd->blv", x[:, -1:], params["embed"])
    return logits, {"self": self_cache, "cross_k": cross_k, "cross_v": cross_v}


def decoder_decode(cfg: EncDecConfig, params, tokens, caches):
    """One decode step: tokens (B,1) -> (logits, new caches)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, "residual")

    def body(x, inputs):
        layer, cache, ck, cv = inputs
        h = rms_norm(x, layer["norm_attn"])
        out, cache = attn_mod.decode_attention(layer["attn"], h, cache)
        x = x + out
        h = rms_norm(x, layer["norm_cross"])
        b = x.shape[0]
        pos = jnp.broadcast_to(cache.length[None].astype(jnp.int32) - 1, (b, 1))
        x = x + _cross_attention(cfg, layer["cross"], h, (ck, cv), pos)
        h = rms_norm(x, layer["norm_ffn"])
        x = x + moe_mod.dense_ffn(layer["ffn"], h)
        return x, cache

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], caches["self"], caches["cross_k"],
                  caches["cross_v"]))
    x = rms_norm(x, params["dec_final_norm"])
    logits = jnp.einsum("bld,vd->blv", x, params["embed"])
    return logits, {"self": new_self, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}
